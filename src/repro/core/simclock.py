"""Virtual-time simulation core: deterministic discrete-event substrates.

Every engine layer (KV store, executors, invoker pools, schedulers, the
fault monitor) charges FaaS latency on a *clock* instead of calling
``time.sleep``/``time.monotonic`` directly. Three implementations share
one interface:

- ``EventClock`` (the default, ``substrate="event"``) is a
  continuation/event-driven scheduler: actors are *frames* — generators
  yielding effect tuples — driven from a single ready queue by one
  driver thread. No OS thread per actor, so a million-task DAG
  simulates without exhausting threads, and a 4096-leaf tree reduction
  runs an order of magnitude faster than the thread substrate.

- ``VirtualClock`` (``substrate="thread"``) is the PR-3 cooperative
  discrete-event scheduler over real threads, kept as a cross-check
  mode: threads register as *actors*; exactly one actor runs at a time
  (a run token), and every blocking operation yields the token through
  the clock. Both virtual substrates replay the same event sequence —
  FIFO ready queues, timers in (deadline, spawn-seq) order, FIFO
  waiters — so they produce bit-identical ``charged_ms`` / kv_stats /
  billing for the same job.

- ``RealtimeClock`` (``time_scale > 0``) is the seed behavior kept for
  sanity cross-checks: charges really sleep ``ms * time_scale / 1e3``
  seconds, and the primitives are the plain ``threading``/``queue``
  ones. ``REPRO_SIM_SCALE`` is only needed for this mode.

All clocks expose the *same* primitive factories (``queue()``,
``lock()``, ``event()``, ``pool()``, ``spawn()``), so the engines
contain no mode branches: they are written once against the clock and
the mode is picked by the cost model.

Effect protocol
---------------

Actor logic is written once as generator functions yielding effect
tuples; the substrate decides how each effect blocks:

- ``("charge", ms)``    — bill ``ms`` simulated ms and advance time.
- ``("get", q, t)``     — blocking ``q.get(timeout=t)`` (seconds;
  ``None`` = forever). ``queue.Empty`` is raised at the yield site.
- ``("acquire", lock)`` — blocking lock acquire (release is a direct
  ``lock.release()`` call).
- ``("wait", ev, t)``   — blocking ``ev.wait(timeout=t)`` (seconds);
  the yield evaluates to the flag.
- ``("flush",)``        — advance time past charges deferred by
  non-yielding code (``simulated_compute`` inside a task function);
  no-op on the thread substrates where charges advance immediately.
- ``("sleep", ms)``     — advance simulated time without billing.

Non-suspending operations (``q.put``, ``ev.set``, ``lock.release``,
``pool.submit``, ``clock.spawn``) remain direct calls on every
substrate. On the thread substrates the shared interpreter
``run_effects`` maps each effect onto the blocking primitive; on the
``EventClock`` the generator IS the continuation and effects park the
frame in the scheduler.

Determinism contract (virtual substrates): actors are scheduled FIFO in
the order they became ready; timers fire in (deadline, registration-seq)
order; queue/lock waiters are served FIFO. Any randomness (invoke-latency
jitter, cold starts, fault injection) is drawn from counters/keys hashed
with seeds — never from wall time — so two runs of the same job produce
identical traces.

Threads that never registered as actors (unit tests driving the KV store
directly, external callers) degrade gracefully: their charges accumulate
``charged_ms`` without advancing virtual time, and their blocking waits
use real condition variables with real timeouts.
"""
from __future__ import annotations

import heapq
import itertools
import queue as _queue
import sys
import threading
import time
import traceback
from collections import deque
from types import GeneratorType
from typing import Any, Callable

__all__ = [
    "BaseClock",
    "EventClock",
    "RealtimeClock",
    "VirtualClock",
    "charge_meter",
    "clock_for_scale",
    "drain_worker_cache",
    "run_effects",
    "simulated_compute",
    "task_clock",
    "worker_cache_size",
]


# ---------------------------------------------------------------------------
# Frame-local context.
#
# On the EventClock many logical actors share ONE driver thread, so
# anything formerly thread-local (the task clock, the billing tap, the
# kv stats sink) must follow the *frame* instead: when frame A suspends
# mid-scope and frame B runs, B must not observe A's context. The
# driver publishes the currently-stepping frame here; thread-locals
# remain the fallback for the thread substrates and external callers.
# ---------------------------------------------------------------------------

_frame_ctx = threading.local()


def _current_frame() -> "_Frame | None":
    return getattr(_frame_ctx, "frame", None)


# ---------------------------------------------------------------------------
# Task-payload compute charging.
#
# Workload DAGs (tree reduction, GEMM, SVD, SVC) declare per-task compute
# duration in *simulated* ms. The executor installs the engine's clock
# around each task-function call; `simulated_compute` charges the
# duration on whatever clock is installed. Outside an engine (sequential
# reference evaluation in tests) it is free: reference results never
# depend on timing.
# ---------------------------------------------------------------------------

_task_clock = threading.local()


class task_clock:
    """Context manager installing ``clock`` as the current task clock."""

    def __init__(self, clock: "BaseClock | None"):
        self.clock = clock

    def __enter__(self) -> None:
        frame = _current_frame()
        self._frame = frame
        if frame is not None:
            self._prev = frame.task_clock
            frame.task_clock = self.clock
        else:
            self._prev = getattr(_task_clock, "clock", None)
            _task_clock.clock = self.clock

    def __exit__(self, *exc: Any) -> None:
        if self._frame is not None:
            self._frame.task_clock = self._prev
        else:
            _task_clock.clock = self._prev


def simulated_compute(ms: float) -> None:
    """Charge ``ms`` simulated milliseconds of task compute on the
    engine clock running this task (no-op outside an engine)."""
    frame = _current_frame()
    if frame is not None:
        clock = frame.task_clock
    else:
        clock = getattr(_task_clock, "clock", None)
    if clock is not None and ms > 0:
        clock.charge(ms)


# ---------------------------------------------------------------------------
# Per-thread charge metering (billing).
#
# The platform model bills an invocation the simulated time its body
# *charges* while running — not a wall-clock delta — because charge
# amounts are identical across clock modes, which makes billed cost
# bit-identical. The tap lives here so the platform layer never has to
# patch clock internals. On the EventClock the accumulator rides on the
# frame (the body suspends and resumes inside the metered scope).
# ---------------------------------------------------------------------------

_charge_tap = threading.local()


class charge_meter:
    """Context manager accumulating this actor's clock charges into
    ``acc[0]`` (a single-element list). Nesting restores the previous
    accumulator on exit; charges while nested land in the innermost."""

    def __init__(self, acc: "list[float]"):
        self.acc = acc

    def __enter__(self) -> "list[float]":
        frame = _current_frame()
        self._frame = frame
        if frame is not None:
            self._prev = frame.charge_acc
            frame.charge_acc = self.acc
        else:
            self._prev = getattr(_charge_tap, "acc", None)
            _charge_tap.acc = self.acc
        return self.acc

    def __exit__(self, *exc: Any) -> None:
        if self._frame is not None:
            self._frame.charge_acc = self._prev
        else:
            _charge_tap.acc = self._prev


# ---------------------------------------------------------------------------
# Worker-thread cache.
#
# The thread substrates spawn hundreds of short-lived actor threads per
# job (invoker lanes, runtime-pool workers, monitors). OS thread
# creation is ~100s of microseconds — a large fraction of a virtual
# run's wall time — so finished workers park here and get re-dispatched
# instead of dying. Recycling is invisible to the simulation: the
# *actor slot* is created deterministically by ``spawn``; which OS
# thread services it is not an event the scheduler can observe.
# ---------------------------------------------------------------------------

_WORKER_CACHE_MAX = 2048
_worker_cache: "list[_CachedWorker]" = []
_worker_cache_lock = threading.Lock()


class _CachedWorker(threading.Thread):
    def __init__(self) -> None:
        super().__init__(daemon=True, name="simclock-worker")
        self._sem = threading.Semaphore(0)
        self._job: Callable[[], None] | None = None
        self.start()

    def run(self) -> None:
        while True:
            self._sem.acquire()
            job, self._job = self._job, None
            if job is None:
                return
            job()  # an escaping exception retires this thread (no recycle)
            with _worker_cache_lock:
                if len(_worker_cache) >= _WORKER_CACHE_MAX:
                    return
                _worker_cache.append(self)

    def dispatch(self, job: "Callable[[], None] | None") -> None:
        self._job = job
        self._sem.release()


def _dispatch_to_worker(job: Callable[[], None]) -> None:
    with _worker_cache_lock:
        worker = _worker_cache.pop() if _worker_cache else None
    (worker or _CachedWorker()).dispatch(job)


def drain_worker_cache() -> int:
    """Retire every cached worker thread and return how many were
    drained. Call between benchmark iterations (or test runs) so idle
    threads from a thread-substrate run don't linger into — and skew
    the wall-time of — event-substrate runs."""
    with _worker_cache_lock:
        workers = _worker_cache[:]
        _worker_cache.clear()
    for worker in workers:
        worker.dispatch(None)  # `run` exits on a None job
    return len(workers)


def worker_cache_size() -> int:
    """Number of idle cached worker threads (observability for tests)."""
    with _worker_cache_lock:
        return len(_worker_cache)


# ---------------------------------------------------------------------------
# Shared interface
# ---------------------------------------------------------------------------


class BaseClock:
    """Accounting shared by all clock implementations."""

    virtual: bool = False

    def __init__(self) -> None:
        self._charge_lock = threading.Lock()
        self.charged_ms = 0.0
        # Opt-in determinism sanitizer (repro.analysis.divergence.Tracer,
        # duck-typed so the substrate never imports the analysis
        # package): when set, every freshly generated effect is
        # journaled via tracer.record(actor, effect, gen). None is free.
        self.tracer: Any = None

    def _account(self, ms: float) -> None:
        with self._charge_lock:
            self.charged_ms += ms
        frame = _current_frame()
        if frame is not None:
            acc = frame.charge_acc
        else:
            acc = getattr(_charge_tap, "acc", None)
        if acc is not None:
            acc[0] += ms

    # subclass API ----------------------------------------------------------
    def charge(self, ms: float) -> None:  # bill + advance simulated time
        raise NotImplementedError

    def now_ms(self) -> float:  # simulated (virtual) / real elapsed ms
        raise NotImplementedError

    def queue(self) -> Any:  # queue.Queue-compatible
        raise NotImplementedError

    def lock(self) -> Any:  # context-manager lock (transfer lanes)
        raise NotImplementedError

    def event(self) -> Any:  # threading.Event-compatible
        raise NotImplementedError

    def pool(self, max_workers: int) -> Any:  # .submit(fn) / .shutdown()
        raise NotImplementedError

    def spawn(self, fn: Callable[[], Any], name: str = "") -> None:
        raise NotImplementedError

    def actor(self) -> Any:  # context manager registering current thread
        raise NotImplementedError

    def run(self, gen: Any) -> Any:
        """Drive an effect generator to completion on this substrate
        and return its value. Non-generators pass through unchanged."""
        return run_effects(self, gen)


def _blocking_actor_label(clock: BaseClock) -> str:
    """Trace label for the thread substrates: ``actor#<seq>`` when the
    clock tracks the current thread as a registered actor (VirtualClock),
    else the thread name. Deterministic on the virtual substrate —
    actors are numbered in registration order."""
    current = getattr(clock, "_current", None)
    if current is not None:
        actor = current()
        if actor is not None and hasattr(actor, "seq"):
            return f"actor#{actor.seq}"
    return threading.current_thread().name


def run_effects(clock: BaseClock, gen: Any) -> Any:
    """Interpret an effect generator on the blocking (thread-based)
    primitives: the shared cross-check path for ``VirtualClock`` and
    ``RealtimeClock``, and for external threads driving one-off
    operations against any clock. Returns the generator's value."""
    if not isinstance(gen, GeneratorType):
        return gen
    if _current_frame() is not None:
        raise RuntimeError(
            "run_effects() called inside an event-driven frame; compose "
            "generators with 'yield from' instead")
    value: Any = None
    exc: BaseException | None = None
    while True:
        try:
            if exc is not None:
                pending, exc = exc, None
                eff = gen.throw(pending)
            else:
                eff = gen.send(value)
            value = None
        except StopIteration as stop:
            return stop.value
        tracer = getattr(clock, "tracer", None)
        if tracer is not None:
            tracer.record(_blocking_actor_label(clock), eff, gen)
        kind = eff[0]
        if kind == "charge":
            clock.charge(eff[1])
        elif kind == "get":
            try:
                value = eff[1].get(timeout=eff[2])
            except _queue.Empty as empty:
                exc = empty
        elif kind == "acquire":
            eff[1].acquire()
        elif kind == "wait":
            value = eff[1].wait(eff[2])
        elif kind == "flush":
            pass  # thread substrates advance time at charge time
        elif kind == "sleep":
            sleep = getattr(clock, "sleep_ms", None)
            if sleep is not None:
                sleep(eff[1])
        else:
            raise RuntimeError(f"unknown clock effect {eff!r}")


# ---------------------------------------------------------------------------
# Real-time clock (the seed behavior, kept for cross-checks)
# ---------------------------------------------------------------------------


class _RealtimePool:
    """Thin ThreadPoolExecutor wrapper pinning the two methods engines
    use, interpreting effect-generator bodies on the worker thread."""

    def __init__(self, clock: BaseClock, max_workers: int):
        from concurrent.futures import ThreadPoolExecutor

        self._clock = clock
        self._tpe = ThreadPoolExecutor(max_workers=max_workers)

    def _run(self, fn: Callable[[], Any]) -> None:
        run_effects(self._clock, fn())

    def submit(self, fn: Callable[[], Any]) -> None:
        self._tpe.submit(self._run, fn)

    def shutdown(self, wait: bool = False,
                 cancel_futures: bool = True) -> None:
        self._tpe.shutdown(wait=wait, cancel_futures=cancel_futures)


class _NullActor:
    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc: Any) -> None:
        return None


class RealtimeClock(BaseClock):
    """Charges simulated latency by really sleeping ``ms * time_scale``."""

    virtual = False

    def __init__(self, time_scale: float):
        super().__init__()
        self.time_scale = time_scale
        self._t0 = time.perf_counter()

    def charge(self, ms: float) -> None:
        if ms <= 0:
            return
        self._account(ms)
        if self.time_scale > 0:
            time.sleep(ms * self.time_scale / 1e3)

    def sleep_ms(self, ms: float) -> None:
        if self.time_scale > 0 and ms > 0:
            time.sleep(ms * self.time_scale / 1e3)

    def now_ms(self) -> float:
        return (time.perf_counter() - self._t0) * 1e3

    def queue(self) -> "_queue.Queue[Any]":
        return _queue.Queue()

    def lock(self) -> threading.Lock:
        return threading.Lock()

    def event(self) -> threading.Event:
        return threading.Event()

    def pool(self, max_workers: int) -> _RealtimePool:
        return _RealtimePool(self, max_workers)

    def spawn(self, fn: Callable[[], Any], name: str = "") -> None:
        def body() -> None:
            run_effects(self, fn())

        _dispatch_to_worker(body)

    def actor(self) -> _NullActor:
        return _NullActor()


# ---------------------------------------------------------------------------
# Thread substrate: cooperative discrete-event scheduling over threads
# ---------------------------------------------------------------------------

_RUNNING = "running"
_READY = "ready"
_BLOCKED = "blocked"

_WAKE_SIGNAL = "signal"
_WAKE_TIMEOUT = "timeout"


class _Actor:
    __slots__ = ("seq", "cond", "state", "wake_reason", "timer")

    def __init__(self, seq: int, mutex: threading.Lock):
        self.seq = seq
        self.cond = threading.Condition(mutex)
        self.state = _READY
        self.wake_reason: str | None = None
        self.timer: "_Timer | None" = None  # pending virtual timeout


class _Timer:
    """Heap entry waking ``owner`` (a thread actor or an event frame —
    both carry ``seq``) at a virtual deadline."""

    __slots__ = ("deadline", "owner", "cancelled")

    def __init__(self, deadline: float, owner: Any):
        self.deadline = deadline
        self.owner = owner
        self.cancelled = False

    def __lt__(self, other: "_Timer") -> bool:  # heap tiebreak
        return (self.deadline, self.owner.seq) < (
            other.deadline, other.owner.seq)


class _ExternalWaiter:
    """A non-actor thread blocked on a clock primitive (tests, legacy
    callers). It waits on a real condition with a real timeout and does
    not hold back virtual-time advancement."""

    __slots__ = ("cond", "signalled")

    def __init__(self, mutex: "threading.Lock | threading.RLock"):
        self.cond = threading.Condition(mutex)
        self.signalled = False


class VirtualClock(BaseClock):
    """Deterministic discrete-event clock over cooperative actor threads.

    Exactly one registered actor holds the run token at any moment; all
    others are parked on per-actor condition variables sharing one mutex.
    Blocking operations release the token; wake-ups re-enter a FIFO ready
    queue. Virtual time jumps to the earliest pending timer only when no
    actor is ready — i.e. when every actor is provably waiting on
    simulated time or on an event another actor will produce.
    """

    virtual = True

    def __init__(self) -> None:
        super().__init__()
        self._mutex = threading.Lock()
        self._now = 0.0
        self._seq = itertools.count()
        self._actors: dict[int, _Actor] = {}  # thread ident -> actor
        self._ready: list[_Actor] = []
        self._running: _Actor | None = None
        self._timers: list[_Timer] = []
        self.switches = 0        # token handoffs (scheduler cost metric)
        self.actors_spawned = 0  # total actor registrations

    # -- introspection ------------------------------------------------------
    def now_ms(self) -> float:
        return self._now

    def _current(self) -> _Actor | None:
        return self._actors.get(threading.get_ident())

    # -- scheduling core (all called with self._mutex held) -----------------
    def _schedule_next(self) -> None:
        """Hand the run token to the next ready actor, advancing virtual
        time to the earliest timer when nobody is ready."""
        while True:
            if self._ready:
                nxt = self._ready.pop(0)
                nxt.state = _RUNNING
                self._running = nxt
                self.switches += 1
                nxt.cond.notify()
                return
            while self._timers and self._timers[0].cancelled:
                heapq.heappop(self._timers)
            if not self._timers:
                # Fully event-blocked (or no actors at all): idle until an
                # external stimulus re-kicks the scheduler.
                self._running = None
                return
            timer = heapq.heappop(self._timers)
            self._now = max(self._now, timer.deadline)
            actor = timer.owner
            actor.timer = None
            actor.wake_reason = _WAKE_TIMEOUT
            actor.state = _READY
            self._ready.append(actor)

    def _kick(self) -> None:
        """Start the scheduler if the simulation is idle (called after an
        external thread made an actor ready or added a timer)."""
        if self._running is None:
            self._schedule_next()

    def _make_ready(self, actor: _Actor) -> None:
        """Move a blocked actor to the ready queue (waker side)."""
        if actor.timer is not None:
            actor.timer.cancelled = True
            actor.timer = None
        actor.wake_reason = _WAKE_SIGNAL
        actor.state = _READY
        self._ready.append(actor)

    def _block(self, actor: _Actor, timeout_ms: float | None) -> str:
        """Release the run token and wait to be woken. Returns the wake
        reason (``signal`` or ``timeout``)."""
        actor.state = _BLOCKED
        actor.wake_reason = None
        if timeout_ms is not None:
            actor.timer = _Timer(self._now + max(0.0, timeout_ms), actor)
            heapq.heappush(self._timers, actor.timer)
        self._schedule_next()
        while actor.state is not _RUNNING:
            actor.cond.wait()
        return actor.wake_reason or _WAKE_SIGNAL

    def _wait_for_token(self, actor: _Actor) -> None:
        while actor.state is not _RUNNING:
            actor.cond.wait()

    # -- actor lifecycle ----------------------------------------------------
    def _register_current(self) -> _Actor:
        with self._mutex:
            actor = _Actor(next(self._seq), self._mutex)
            actor.state = _READY
            self._actors[threading.get_ident()] = actor
            self._ready.append(actor)
            self._kick()
            self._wait_for_token(actor)
            return actor

    def _deregister_current(self) -> None:
        with self._mutex:
            actor = self._actors.pop(threading.get_ident(), None)
            if actor is None:
                return
            if self._running is actor:
                self._schedule_next()

    class _ActorContext:
        def __init__(self, clock: "VirtualClock"):
            self.clock = clock

        def __enter__(self) -> None:
            self.clock._register_current()

        def __exit__(self, *exc: Any) -> None:
            self.clock._deregister_current()

    def actor(self) -> "_ActorContext":
        return VirtualClock._ActorContext(self)

    def run(self, gen: Any) -> Any:
        """Drive an effect generator as a registered actor (registering
        the calling thread for the duration if it isn't one already)."""
        if not isinstance(gen, GeneratorType):
            return gen
        if self._current() is not None:
            return run_effects(self, gen)
        with self.actor():
            return run_effects(self, gen)

    def spawn(self, fn: Callable[[], Any], name: str = "") -> None:
        # The actor slot enters the ready queue HERE, on the spawning
        # thread, so scheduling order is a pure function of the event
        # sequence — not of how quickly the OS starts (or recycles) the
        # worker thread that will service it.
        with self._mutex:
            actor = _Actor(next(self._seq), self._mutex)
            actor.state = _READY
            self._ready.append(actor)
            self.actors_spawned += 1
            self._kick()

        def body() -> None:
            with self._mutex:
                self._actors[threading.get_ident()] = actor
                self._wait_for_token(actor)
            try:
                r = fn()
                if isinstance(r, GeneratorType):
                    run_effects(self, r)
            finally:
                self._deregister_current()

        _dispatch_to_worker(body)

    # -- time ---------------------------------------------------------------
    def sleep_ms(self, ms: float) -> None:
        with self._mutex:
            actor = self._current()
            if actor is None or self._running is not actor:
                return  # non-actor thread: virtual time is not its to spend
            self._block(actor, ms)

    def charge(self, ms: float) -> None:
        if ms <= 0:
            return
        self._account(ms)
        self.sleep_ms(ms)

    # -- primitives ---------------------------------------------------------
    def queue(self) -> "VirtualQueue":
        return VirtualQueue(self)

    def lock(self) -> "VirtualLock":
        return VirtualLock(self)

    def event(self) -> "VirtualEvent":
        return VirtualEvent(self)

    def pool(self, max_workers: int) -> "VirtualPool":
        return VirtualPool(self, max_workers)


class VirtualQueue:
    """``queue.Queue``-compatible FIFO whose blocking ``get`` cooperates
    with the virtual clock. ``timeout`` is *simulated seconds* for actor
    threads and real seconds for non-actor threads."""

    def __init__(self, clock: VirtualClock):
        self._clock = clock
        self._items: list[Any] = []
        self._waiters: list[_Actor | _ExternalWaiter] = []

    def put(self, item: Any) -> None:
        clock = self._clock
        with clock._mutex:
            self._items.append(item)
            if self._waiters:
                waiter = self._waiters.pop(0)
                if isinstance(waiter, _ExternalWaiter):
                    waiter.signalled = True
                    waiter.cond.notify()
                else:
                    clock._make_ready(waiter)
                    clock._kick()

    def get(self, timeout: float | None = None) -> Any:
        clock = self._clock
        with clock._mutex:
            actor = clock._current()
            if actor is not None and clock._running is actor:
                deadline = (None if timeout is None
                            else clock._now + timeout * 1e3)
                while not self._items:
                    remaining = (None if deadline is None
                                 else deadline - clock._now)
                    if remaining is not None and remaining <= 0:
                        raise _queue.Empty
                    self._waiters.append(actor)
                    reason = clock._block(actor, remaining)
                    if reason == _WAKE_TIMEOUT:
                        if actor in self._waiters:
                            self._waiters.remove(actor)
                        raise _queue.Empty
                return self._items.pop(0)
            # Non-actor thread: real wait, real timeout.
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while not self._items:
                waiter = _ExternalWaiter(clock._mutex)
                self._waiters.append(waiter)
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    self._waiters.remove(waiter)
                    raise _queue.Empty
                if not waiter.cond.wait(remaining):
                    if waiter in self._waiters:
                        self._waiters.remove(waiter)
                    if not waiter.signalled:
                        raise _queue.Empty
            return self._items.pop(0)

    def empty(self) -> bool:
        with self._clock._mutex:
            return not self._items

    def drain(self) -> "list[Any]":
        """Atomically remove and return every queued item (pool shutdown
        with ``cancel_futures``: queued-but-unstarted work is dropped)."""
        with self._clock._mutex:
            items, self._items = self._items, []
            return items


class VirtualLock:
    """Transfer-lane lock held across simulated transfers. FIFO handoff:
    ``release`` passes ownership directly to the longest-waiting thread,
    which keeps lane-contention outcomes deterministic."""

    def __init__(self, clock: VirtualClock):
        self._clock = clock
        self._owner: Any = None  # _Actor, _ExternalWaiter, or thread ident
        self._waiters: list[_Actor | _ExternalWaiter] = []

    def acquire(self) -> None:
        clock = self._clock
        with clock._mutex:
            actor = clock._current()
            if actor is not None and clock._running is actor:
                if self._owner is None:
                    self._owner = actor
                    return
                self._waiters.append(actor)
                clock._block(actor, None)  # woken owning the lock
                return
            ident = threading.get_ident()
            if self._owner is None:
                self._owner = ident
                return
            waiter = _ExternalWaiter(clock._mutex)
            self._waiters.append(waiter)
            while not waiter.signalled:
                waiter.cond.wait()
            self._owner = ident

    def release(self) -> None:
        clock = self._clock
        with clock._mutex:
            if not self._waiters:
                self._owner = None
                return
            waiter = self._waiters.pop(0)
            if isinstance(waiter, _ExternalWaiter):
                self._owner = waiter  # placeholder until the thread wakes
                waiter.signalled = True
                waiter.cond.notify()
            else:
                self._owner = waiter
                clock._make_ready(waiter)
                clock._kick()

    def __enter__(self) -> "VirtualLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()


class VirtualEvent:
    """``threading.Event``-compatible; ``wait`` timeout is simulated
    seconds for actors, real seconds for non-actor threads."""

    def __init__(self, clock: VirtualClock):
        self._clock = clock
        self._flag = False
        self._waiters: list[_Actor | _ExternalWaiter] = []

    def is_set(self) -> bool:
        return self._flag

    def set(self) -> None:
        clock = self._clock
        with clock._mutex:
            self._flag = True
            waiters, self._waiters = self._waiters, []
            kicked = False
            for waiter in waiters:
                if isinstance(waiter, _ExternalWaiter):
                    waiter.signalled = True
                    waiter.cond.notify()
                else:
                    clock._make_ready(waiter)
                    kicked = True
            if kicked:
                clock._kick()

    def wait(self, timeout: float | None = None) -> bool:
        clock = self._clock
        with clock._mutex:
            if self._flag:
                return True
            actor = clock._current()
            if actor is not None and clock._running is actor:
                self._waiters.append(actor)
                reason = clock._block(
                    actor, None if timeout is None else timeout * 1e3)
                if reason == _WAKE_TIMEOUT and actor in self._waiters:
                    self._waiters.remove(actor)
                return self._flag
            waiter = _ExternalWaiter(clock._mutex)
            self._waiters.append(waiter)
            waiter.cond.wait(timeout)
            if waiter in self._waiters:
                self._waiters.remove(waiter)
            return self._flag


class VirtualPool:
    """Executor-runtime stand-in for ``ThreadPoolExecutor``: worker
    actors are created lazily up to ``max_workers``, so an 8k-task sweep
    only materializes as many workers as are ever simultaneously busy.
    Queued bodies do NOT hold back virtual time — a full pool models the
    provider's concurrency limit. Shared by both virtual substrates:
    the worker is an effect generator, so on the thread substrate it
    runs as a cooperative actor and on the event substrate as a frame."""

    def __init__(self, clock: BaseClock, max_workers: int):
        self._clock = clock
        self._max_workers = max(1, max_workers)
        self._q = clock.queue()
        self._state_lock = threading.Lock()
        self._workers = 0
        self._idle = 0
        self._closed = False

    def submit(self, fn: Callable[[], Any]) -> None:
        with self._state_lock:
            if self._closed:
                raise RuntimeError("cannot schedule new futures after "
                                   "shutdown")
            spawn = self._idle == 0 and self._workers < self._max_workers
            if spawn:
                self._workers += 1
                n = self._workers
        self._q.put(fn)
        if spawn:
            self._clock.spawn(self._worker, name=f"vpool-{n}")

    def _worker(self) -> Any:
        while True:
            with self._state_lock:
                self._idle += 1
            item = yield ("get", self._q, None)
            with self._state_lock:
                self._idle -= 1
            if item is None:
                return
            r = item()
            if isinstance(r, GeneratorType):
                yield from r

    def shutdown(self, wait: bool = False,
                 cancel_futures: bool = True) -> None:
        with self._state_lock:
            if self._closed:
                return
            self._closed = True
            n = self._workers
        if cancel_futures:
            # Drop queued-but-unstarted bodies (matching the
            # ThreadPoolExecutor contract the realtime pool inherits).
            # Before this, a torn-down job's queued executors still ran
            # to completion behind the shutdown sentinels — harmless when
            # the substrate died with the job, a capacity leak once
            # platform and store outlive it.
            self._q.drain()
        for _ in range(n):
            self._q.put(None)


# ---------------------------------------------------------------------------
# Event substrate: continuation frames on one driver thread
# ---------------------------------------------------------------------------


class _Frame:
    """One logical actor on the EventClock: a (not-yet-started) body or
    its live generator, plus the park/wake state the driver needs."""

    __slots__ = ("seq", "fn", "gen", "name", "wait", "wake_reason", "timer",
                 "deferred_ms", "charge_acc", "task_clock", "sink",
                 "done", "root", "result", "exc")

    def __init__(self, seq: int, fn: "Callable[[], Any] | None",
                 name: str, root: bool = False):
        self.seq = seq
        self.fn = fn
        self.gen: Any = None
        self.name = name
        self.wait: "tuple[Any, ...] | None" = None
        self.wake_reason: str | None = None
        self.timer: _Timer | None = None
        self.deferred_ms = 0.0   # charges awaiting a ("flush",)
        self.charge_acc: "list[float] | None" = None
        self.task_clock: Any = None
        self.sink: Any = None    # kv-stats sink (namespace mirroring)
        self.done = False
        self.root = root
        self.result: Any = None
        self.exc: BaseException | None = None


class EventClock(BaseClock):
    """Continuation/event-driven discrete-event clock: the default
    substrate. Actors are *frames* — effect generators — dispatched
    FIFO from one ready deque by whichever thread called ``run()``; no
    OS thread per actor. Scheduling replays the VirtualClock event
    order exactly (FIFO ready, timers in (deadline, seq) order, FIFO
    waiters, one waiter woken per ``put``), so both virtual substrates
    produce bit-identical charges for the same job.

    Charges issued by non-yielding code inside a frame (a task function
    calling ``simulated_compute``) are *deferred*: billed immediately,
    applied to virtual time at the next suspension or explicit
    ``("flush",)`` effect.

    External (non-frame) threads interoperate the same way they do with
    the VirtualClock: registered via ``actor()``, their charges drive
    the frame scheduler forward; unregistered, they bill without
    advancing time and block on real condition variables."""

    virtual = True

    def __init__(self) -> None:
        super().__init__()
        # RLock: frame code runs under the driver's mutex and re-enters
        # it through every primitive call (put/set/release/spawn).
        self._mutex = threading.RLock()
        self._cond = threading.Condition(self._mutex)
        self._now = 0.0
        self._seq = itertools.count()
        self._ready: "deque[_Frame]" = deque()
        self._timers: list[_Timer] = []
        self._driving = False
        self._external_actors: dict[int, int] = {}  # ident -> depth
        self.switches = 0        # frame dispatches (scheduler cost metric)
        self.actors_spawned = 0  # total frames spawned

    # -- introspection ------------------------------------------------------
    def now_ms(self) -> float:
        return self._now

    def _current(self) -> "_Frame | None":
        return _current_frame()

    # -- driver -------------------------------------------------------------
    def run(self, gen: Any) -> Any:
        """Drive ``gen`` as a root frame until it completes, then drain
        any frames it made ready (sentinel cleanup), and return its
        value. Frames still parked on timers stay parked — exactly like
        leftover thread actors — and resume on the next ``run()``."""
        if not isinstance(gen, GeneratorType):
            return gen
        if _current_frame() is not None:
            raise RuntimeError(
                "EventClock.run() called from inside a frame; compose "
                "generators with 'yield from' instead")
        with self._mutex:
            if self._driving:
                raise RuntimeError("EventClock is already being driven")
            root = _Frame(next(self._seq), None, "root", root=True)
            root.gen = gen
            self._ready.append(root)
            self._driving = True
            try:
                self._drive(root)
            finally:
                self._driving = False
        if root.exc is not None:
            raise root.exc
        return root.result

    def _drive(self, root: _Frame) -> None:
        ready = self._ready
        timers = self._timers
        while not root.done:
            if ready:
                self._dispatch(ready.popleft())
                continue
            while timers and timers[0].cancelled:
                heapq.heappop(timers)
            if timers:
                timer = heapq.heappop(timers)
                self._now = max(self._now, timer.deadline)
                frame = timer.owner
                frame.timer = None
                frame.wake_reason = _WAKE_TIMEOUT
                ready.append(frame)
                continue
            # Fully event-blocked: idle until an external stimulus.
            self._cond.wait()
        while ready:
            # Root finished: run frames its teardown made ready (pool
            # sentinels, lane shutdowns) so they don't leak into the
            # next job's ready order; timer-parked frames stay parked.
            self._dispatch(ready.popleft())

    def _dispatch(self, frame: _Frame) -> None:
        self.switches += 1
        wait, frame.wait = frame.wait, None
        reason, frame.wake_reason = frame.wake_reason, None
        if wait is None:  # first dispatch
            self._step(frame, None, None, None)
            return
        kind = wait[0]
        if kind == "get":
            q, deadline = wait[1], wait[2]
            if reason == _WAKE_TIMEOUT:
                try:
                    q._waiters.remove(frame)
                except ValueError:
                    pass
                self._step(frame, None, _queue.Empty(), None)
                return
            if q._items:
                self._step(frame, q._items.pop(0), None, None)
                return
            # Signalled but the item was taken: wait out the remainder
            # (mirrors the VirtualQueue re-check loop).
            remaining = None if deadline is None else deadline - self._now
            if remaining is not None and remaining <= 0:
                self._step(frame, None, _queue.Empty(), None)
                return
            q._waiters.append(frame)
            self._park(frame, ("get", q, deadline), remaining)
            return
        if kind == "wait":
            ev = wait[1]
            if reason == _WAKE_TIMEOUT:
                try:
                    ev._waiters.remove(frame)
                except ValueError:
                    pass
            self._step(frame, ev._flag, None, None)
            return
        if kind == "retire":
            self._finalize(frame)
            return
        if kind == "replay":
            self._step(frame, None, None, wait[1])
            return
        # "resume" (charge/flush/sleep) or "acquire" (woken owning)
        self._step(frame, None, None, None)

    def _park(self, frame: _Frame, wait: "tuple[Any, ...]",
              timeout_ms: float | None) -> None:
        frame.wait = wait
        if timeout_ms is not None:
            timer = _Timer(self._now + max(0.0, timeout_ms), frame)
            frame.timer = timer
            heapq.heappush(self._timers, timer)

    def _make_ready(self, frame: _Frame) -> None:
        if frame.timer is not None:
            frame.timer.cancelled = True
            frame.timer = None
        frame.wake_reason = _WAKE_SIGNAL
        self._ready.append(frame)
        self._cond.notify_all()  # wake an idle driver

    def _defer_flush(self, frame: _Frame, eff: "tuple[Any, ...]") -> None:
        """A suspending effect arrived with compute charges still
        deferred: advance time past them first, then replay the effect
        (keeps the time trajectory identical to the thread substrate,
        where those charges advanced time when issued)."""
        self._park(frame, ("replay", eff), frame.deferred_ms)
        frame.deferred_ms = 0.0

    def _step(self, frame: _Frame, value: Any, exc: "BaseException | None",
              replay: "tuple[Any, ...] | None") -> None:
        _frame_ctx.frame = frame
        try:
            gen = frame.gen
            if gen is None:
                try:
                    r = frame.fn()  # type: ignore[misc]
                except BaseException as e:
                    self._fail(frame, e)
                    return
                frame.fn = None
                if not isinstance(r, GeneratorType):
                    self._retire(frame, r)
                    return
                frame.gen = gen = r
            while True:
                if replay is not None:
                    eff, replay = replay, None
                else:
                    try:
                        if exc is not None:
                            pending, exc = exc, None
                            eff = gen.throw(pending)
                        else:
                            eff = gen.send(value)
                        value = None
                    except StopIteration as stop:
                        self._retire(frame, stop.value)
                        return
                    except BaseException as e:
                        self._fail(frame, e)
                        return
                    # Journal only freshly generated effects — a replayed
                    # effect (deferred-flush re-issue) was already
                    # recorded when the generator first yielded it.
                    tracer = self.tracer
                    if tracer is not None:
                        tracer.record(
                            f"{frame.name or 'frame'}#{frame.seq}", eff, gen)
                kind = eff[0]
                if kind == "charge":
                    ms = eff[1]
                    if ms <= 0:
                        continue
                    self._account(ms)
                    self._park(frame, ("resume",), ms + frame.deferred_ms)
                    frame.deferred_ms = 0.0
                    return
                if kind == "get":
                    if frame.deferred_ms > 0.0:
                        self._defer_flush(frame, eff)
                        return
                    q, timeout = eff[1], eff[2]
                    if q._items:
                        value = q._items.pop(0)
                        continue
                    if timeout is not None and timeout <= 0:
                        exc = _queue.Empty()
                        continue
                    deadline = (None if timeout is None
                                else self._now + timeout * 1e3)
                    q._waiters.append(frame)
                    self._park(frame, ("get", q, deadline),
                               None if timeout is None else timeout * 1e3)
                    return
                if kind == "acquire":
                    if frame.deferred_ms > 0.0:
                        self._defer_flush(frame, eff)
                        return
                    lk = eff[1]
                    if lk._owner is None:
                        lk._owner = frame
                        continue
                    lk._waiters.append(frame)
                    self._park(frame, ("acquire", lk), None)
                    return
                if kind == "wait":
                    if frame.deferred_ms > 0.0:
                        self._defer_flush(frame, eff)
                        return
                    ev, timeout = eff[1], eff[2]
                    if ev._flag:
                        value = True
                        continue
                    ev._waiters.append(frame)
                    self._park(frame, ("wait", ev),
                               None if timeout is None else timeout * 1e3)
                    return
                if kind == "flush":
                    if frame.deferred_ms > 0.0:
                        self._park(frame, ("resume",), frame.deferred_ms)
                        frame.deferred_ms = 0.0
                        return
                    continue
                if kind == "sleep":
                    self._park(frame, ("resume",),
                               max(0.0, eff[1]) + frame.deferred_ms)
                    frame.deferred_ms = 0.0
                    return
                self._fail(frame, RuntimeError(
                    f"unknown clock effect {eff!r}"))
                return
        finally:
            _frame_ctx.frame = None

    def _retire(self, frame: _Frame, result: Any) -> None:
        frame.result = result
        if frame.deferred_ms > 0.0:
            # Auto-flush trailing compute charges so the frame's time
            # footprint matches the thread substrate's.
            self._park(frame, ("retire",), frame.deferred_ms)
            frame.deferred_ms = 0.0
            return
        self._finalize(frame)

    def _finalize(self, frame: _Frame) -> None:
        frame.done = True
        frame.gen = None
        frame.fn = None

    def _fail(self, frame: _Frame, exc: BaseException) -> None:
        frame.gen = None
        frame.fn = None
        frame.done = True
        if frame.root:
            frame.exc = exc
            return
        # Mirror the thread substrate: an exception escaping a spawned
        # actor body is reported (threading excepthook), not raised
        # into the scheduler.
        print(f"Exception in frame {frame.name!r}:", file=sys.stderr)
        traceback.print_exception(type(exc), exc, exc.__traceback__)

    # -- actor lifecycle ----------------------------------------------------
    def spawn(self, fn: Callable[[], Any], name: str = "") -> None:
        with self._mutex:
            frame = _Frame(next(self._seq), fn, name)
            self._ready.append(frame)
            self.actors_spawned += 1
            self._cond.notify_all()

    class _ExternalActorContext:
        def __init__(self, clock: "EventClock"):
            self.clock = clock

        def __enter__(self) -> None:
            ident = threading.get_ident()
            with self.clock._mutex:
                actors = self.clock._external_actors
                actors[ident] = actors.get(ident, 0) + 1

        def __exit__(self, *exc: Any) -> None:
            ident = threading.get_ident()
            with self.clock._mutex:
                actors = self.clock._external_actors
                depth = actors.get(ident, 0) - 1
                if depth <= 0:
                    actors.pop(ident, None)
                else:
                    actors[ident] = depth

    def actor(self) -> "_ExternalActorContext":
        """Register the calling (external) thread as an actor: its
        charges drive the frame scheduler — advancing virtual time and
        firing parked frames' timers — exactly like a thread-substrate
        actor's charges let other actors run."""
        return EventClock._ExternalActorContext(self)

    # -- time ---------------------------------------------------------------
    def charge(self, ms: float) -> None:
        if ms <= 0:
            return
        frame = _current_frame()
        if frame is not None:
            # Non-yielding code inside a frame (simulated_compute in a
            # task function): bill now, advance at the next suspension.
            self._account(ms)
            frame.deferred_ms += ms
            return
        if threading.get_ident() in self._external_actors:
            def once() -> Any:
                yield ("charge", ms)

            self.run(once())
            return
        self._account(ms)

    def sleep_ms(self, ms: float) -> None:
        frame = _current_frame()
        if frame is not None:
            frame.deferred_ms += max(0.0, ms)
            return
        if threading.get_ident() in self._external_actors:
            def once() -> Any:
                yield ("sleep", ms)

            self.run(once())

    # -- primitives ---------------------------------------------------------
    def queue(self) -> "EventQueue":
        return EventQueue(self)

    def lock(self) -> "EventLock":
        return EventLock(self)

    def event(self) -> "EventEvent":
        return EventEvent(self)

    def pool(self, max_workers: int) -> VirtualPool:
        return VirtualPool(self, max_workers)


class EventQueue:
    """``queue.Queue``-compatible FIFO for the event substrate: frames
    suspend via ``("get", q, timeout)`` effects (simulated-seconds
    timeout); external threads block on real condvars with real
    timeouts, exactly like the VirtualQueue non-actor path."""

    def __init__(self, clock: EventClock):
        self._clock = clock
        self._items: list[Any] = []
        self._waiters: list[Any] = []  # _Frame | _ExternalWaiter, FIFO

    def put(self, item: Any) -> None:
        clock = self._clock
        with clock._mutex:
            self._items.append(item)
            if self._waiters:
                waiter = self._waiters.pop(0)
                if isinstance(waiter, _ExternalWaiter):
                    waiter.signalled = True
                    waiter.cond.notify()
                else:
                    clock._make_ready(waiter)

    def get(self, timeout: float | None = None) -> Any:
        if _current_frame() is not None:
            raise RuntimeError(
                "blocking get() inside a frame would deadlock the "
                "driver; yield ('get', q, timeout) instead")
        clock = self._clock
        with clock._mutex:
            deadline = (None if timeout is None
                        else time.monotonic() + timeout)
            while not self._items:
                waiter = _ExternalWaiter(clock._mutex)
                self._waiters.append(waiter)
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    self._waiters.remove(waiter)
                    raise _queue.Empty
                if not waiter.cond.wait(remaining):
                    if waiter in self._waiters:
                        self._waiters.remove(waiter)
                    if not waiter.signalled:
                        raise _queue.Empty
            return self._items.pop(0)

    def empty(self) -> bool:
        with self._clock._mutex:
            return not self._items

    def drain(self) -> "list[Any]":
        with self._clock._mutex:
            items, self._items = self._items, []
            return items


class EventLock:
    """Transfer-lane lock for the event substrate. Frames acquire via
    ``("acquire", lock)`` effects; ``release`` is a direct call with
    FIFO ownership handoff (deterministic lane contention)."""

    def __init__(self, clock: EventClock):
        self._clock = clock
        self._owner: Any = None  # _Frame, _ExternalWaiter, or thread ident
        self._waiters: list[Any] = []

    def acquire(self) -> None:
        if _current_frame() is not None:
            raise RuntimeError(
                "blocking acquire() inside a frame would deadlock the "
                "driver; yield ('acquire', lock) instead")
        clock = self._clock
        with clock._mutex:
            ident = threading.get_ident()
            if self._owner is None:
                self._owner = ident
                return
            waiter = _ExternalWaiter(clock._mutex)
            self._waiters.append(waiter)
            while not waiter.signalled:
                waiter.cond.wait()
            self._owner = ident

    def release(self) -> None:
        clock = self._clock
        with clock._mutex:
            if not self._waiters:
                self._owner = None
                return
            waiter = self._waiters.pop(0)
            self._owner = waiter
            if isinstance(waiter, _ExternalWaiter):
                waiter.signalled = True
                waiter.cond.notify()
            else:
                clock._make_ready(waiter)  # dispatched owning the lock

    def __enter__(self) -> "EventLock":
        self.acquire()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.release()


class EventEvent:
    """``threading.Event``-compatible flag for the event substrate.
    Frames wait via ``("wait", ev, timeout)`` effects; ``set`` wakes
    every waiter in FIFO order."""

    def __init__(self, clock: EventClock):
        self._clock = clock
        self._flag = False
        self._waiters: list[Any] = []

    def is_set(self) -> bool:
        return self._flag

    def set(self) -> None:
        clock = self._clock
        with clock._mutex:
            self._flag = True
            waiters, self._waiters = self._waiters, []
            for waiter in waiters:
                if isinstance(waiter, _ExternalWaiter):
                    waiter.signalled = True
                    waiter.cond.notify()
                else:
                    clock._make_ready(waiter)

    def wait(self, timeout: float | None = None) -> bool:
        if _current_frame() is not None:
            raise RuntimeError(
                "blocking wait() inside a frame would deadlock the "
                "driver; yield ('wait', event, timeout) instead")
        clock = self._clock
        with clock._mutex:
            if self._flag:
                return True
            waiter = _ExternalWaiter(clock._mutex)
            self._waiters.append(waiter)
            waiter.cond.wait(timeout)
            if waiter in self._waiters:
                self._waiters.remove(waiter)
            return self._flag


# ---------------------------------------------------------------------------
# Mode selection
# ---------------------------------------------------------------------------


def clock_for_scale(time_scale: float,
                    substrate: str = "event") -> BaseClock:
    """``time_scale > 0`` keeps the seed real-time mode for
    cross-checks; otherwise ``substrate`` picks the virtual engine:
    ``"event"`` (default) is the continuation scheduler, ``"thread"``
    the PR-3 thread-per-actor cross-check mode."""
    if time_scale > 0:
        return RealtimeClock(time_scale)
    if substrate == "thread":
        return VirtualClock()
    if substrate == "event":
        return EventClock()
    raise ValueError(f"unknown simulation substrate {substrate!r} "
                     "(expected 'event' or 'thread')")
