"""WUKONG core: decentralized serverless DAG engine (the paper's contribution)."""
from repro.core.api import GraphBuilder, delayed_graph
from repro.core.cache import (
    CacheConfig,
    CacheRegistry,
    CacheStats,
    ExecutorCache,
)
from repro.core.dag import (
    DAG,
    EXPAND_BASE,
    DynamicDAG,
    Expansion,
    ExpansionDelta,
    ExpansionError,
    Task,
    TaskRef,
    expansion_base_key,
)
from repro.core.engine import (
    ENGINES,
    CentralizedConfig,
    EngineConfig,
    JobError,
    JobReport,
    JobSubstrate,
    ParallelInvokerEngine,
    PubSubEngine,
    ServerfulConfig,
    ServerfulEngine,
    StrawmanEngine,
    WukongEngine,
)
from repro.core.faults import (
    FaultConfig,
    FaultInjector,
    FaultStats,
    SimulatedTaskFailure,
)
from repro.core.kvstore import PURGED, CostModel, KVNamespace, ShardedKVStore
from repro.core.optimize import (
    ALL_PASSES,
    NO_PASSES,
    CompiledDAG,
    OptimizeConfig,
    PassStats,
    compile_dag,
)
from repro.core.orchestrator import (
    JobOrchestrator,
    JobRequest,
    OrchestratorConfig,
    OrchestratorCrashed,
    OrchestratorReport,
    Substrate,
    TenantSpec,
    WorkloadConfig,
    generate_workload,
)
from repro.core.schedule import StaticSchedule, generate_static_schedules
from repro.core.simclock import (
    EventClock,
    RealtimeClock,
    VirtualClock,
    clock_for_scale,
    drain_worker_cache,
    run_effects,
    simulated_compute,
    worker_cache_size,
)
from repro.core.statemachine import (
    ADMITTED,
    CANCELLED,
    COMPLETED,
    CONTROL_NS,
    FAILED,
    PENDING,
    RUNNING,
    TERMINAL_STATES,
    InvalidTransition,
    JobStateMachine,
)
from repro.core.triggers import (
    TRIGGER_NS,
    TRIGGER_SOURCES,
    StreamConfig,
    StreamingReport,
    TriggerBus,
    TriggerRule,
    stream_arrivals,
    stream_source,
)


def __getattr__(name):
    # Lazy re-export of the platform surface (PEP 562): an eager import
    # would close the repro.platform -> repro.core.kvstore ->
    # repro.core.__init__ cycle and break `import repro.platform` in a
    # fresh process.
    if name in ("FaaSPlatform", "PlatformConfig"):
        import repro.platform

        return getattr(repro.platform, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "DAG", "Task", "TaskRef", "GraphBuilder", "delayed_graph",
    "DynamicDAG", "Expansion", "ExpansionDelta", "ExpansionError",
    "EXPAND_BASE", "expansion_base_key",
    "ENGINES", "EngineConfig", "CentralizedConfig", "ServerfulConfig",
    "JobError", "JobReport", "JobSubstrate", "WukongEngine",
    "StrawmanEngine", "PubSubEngine", "ParallelInvokerEngine",
    "ServerfulEngine",
    "FaultConfig", "FaultInjector", "FaultStats", "SimulatedTaskFailure",
    "CacheConfig", "CacheStats", "ExecutorCache", "CacheRegistry",
    "CostModel", "ShardedKVStore", "KVNamespace", "PURGED",
    "TriggerBus", "TriggerRule", "StreamConfig", "StreamingReport",
    "TRIGGER_NS", "TRIGGER_SOURCES", "stream_arrivals", "stream_source",
    "JobOrchestrator", "JobRequest", "OrchestratorConfig",
    "OrchestratorCrashed", "OrchestratorReport", "Substrate", "TenantSpec",
    "WorkloadConfig", "generate_workload",
    "JobStateMachine", "InvalidTransition", "CONTROL_NS",
    "PENDING", "ADMITTED", "RUNNING", "COMPLETED", "FAILED", "CANCELLED",
    "TERMINAL_STATES",
    "StaticSchedule", "generate_static_schedules",
    "OptimizeConfig", "CompiledDAG", "PassStats", "compile_dag",
    "ALL_PASSES", "NO_PASSES",
    "EventClock", "VirtualClock", "RealtimeClock", "clock_for_scale",
    "run_effects", "drain_worker_cache", "worker_cache_size",
    "simulated_compute",
    "PlatformConfig", "FaaSPlatform",
]
