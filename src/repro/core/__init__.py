"""WUKONG core: decentralized serverless DAG engine (the paper's contribution)."""
from repro.core.api import GraphBuilder, delayed_graph
from repro.core.dag import DAG, Task, TaskRef
from repro.core.engine import (
    ENGINES,
    CentralizedConfig,
    EngineConfig,
    JobError,
    JobReport,
    ParallelInvokerEngine,
    PubSubEngine,
    ServerfulConfig,
    ServerfulEngine,
    StrawmanEngine,
    WukongEngine,
)
from repro.core.faults import FaultConfig, SimulatedTaskFailure
from repro.core.kvstore import CostModel, ShardedKVStore
from repro.core.optimize import (
    ALL_PASSES,
    NO_PASSES,
    CompiledDAG,
    OptimizeConfig,
    PassStats,
    compile_dag,
)
from repro.core.schedule import StaticSchedule, generate_static_schedules
from repro.core.simclock import (
    RealtimeClock,
    VirtualClock,
    clock_for_scale,
    simulated_compute,
)

__all__ = [
    "DAG", "Task", "TaskRef", "GraphBuilder", "delayed_graph",
    "ENGINES", "EngineConfig", "CentralizedConfig", "ServerfulConfig",
    "JobError", "JobReport", "WukongEngine", "StrawmanEngine",
    "PubSubEngine", "ParallelInvokerEngine", "ServerfulEngine",
    "FaultConfig", "SimulatedTaskFailure", "CostModel", "ShardedKVStore",
    "StaticSchedule", "generate_static_schedules",
    "OptimizeConfig", "CompiledDAG", "PassStats", "compile_dag",
    "ALL_PASSES", "NO_PASSES",
    "VirtualClock", "RealtimeClock", "clock_for_scale", "simulated_compute",
]
