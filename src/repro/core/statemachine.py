"""Durable job lifecycle state machine (the control plane's source of
truth).

The orchestrator used to hold every job's lifecycle in process memory,
so an orchestrator crash lost all of it. Following Triggerflow's
event-sourcing design (PAPERS.md, arxiv 2006.08654) and the
rmhgeoapi CoreMachine template (`/root/related/rob634__rmhgeoapi/`),
job state now lives in the shared :class:`ShardedKVStore` as an
append-only journal under a control-plane namespace:

    PENDING -> ADMITTED -> RUNNING -> {COMPLETED, FAILED, CANCELLED}

Transitions are **monotonic** (a journal entry can only move a job to a
strictly higher lifecycle rank; the first terminal state wins) and
therefore **replay-safe**: replaying the journal any number of times,
with any suffix of duplicate entries, folds to the same state. That is
what lets a fresh orchestrator instance recover from a crash by
scanning the journal — duplicates appended by the crashed generation
are no-ops, not corruption.

Every append and scan is charged through the normal KV cost model
(`journal_append_g` / `journal_scan_g`): durability is a real cost the
control plane pays on the same store the data plane contends for.

Task-level lifecycle is deliberately NOT journaled per-transition: task
durability already comes from the data plane's idempotent primitives
(``put_if_absent`` task outputs, edge-set fan-in counters), so a
resumed job re-walks its DAG and skips any task whose durable output
exists. Journaling only job-level transitions keeps the journal
O(jobs), not O(tasks).
"""
from __future__ import annotations

import threading
from typing import Any

from .kvstore import KVNamespace, ShardedKVStore

# Lifecycle states.
PENDING = "PENDING"        # submitted, journaled, not yet admitted
ADMITTED = "ADMITTED"      # passed admission control
RUNNING = "RUNNING"        # runner actor dispatched
COMPLETED = "COMPLETED"    # terminal: finished, results recorded
FAILED = "FAILED"          # terminal: job raised
CANCELLED = "CANCELLED"    # terminal: cancelled before/while running

TERMINAL_STATES = frozenset((COMPLETED, FAILED, CANCELLED))

_RANK = {PENDING: 0, ADMITTED: 1, RUNNING: 2,
         COMPLETED: 3, FAILED: 3, CANCELLED: 3}

# The control plane's reserved namespace in the shared store. Job
# namespaces are "job<N>", tenants are "t-*"/"tenant-*"; the dunder
# prefix keeps it collision-free.
CONTROL_NS = "__control__"

# Journal id within the control namespace.
JOB_JOURNAL = "journal"


class InvalidTransition(ValueError):
    """An entry names a state outside the lifecycle lattice."""


def check_state(state: str) -> None:
    if state not in _RANK:
        raise InvalidTransition(
            f"unknown lifecycle state {state!r}; "
            f"expected one of {sorted(_RANK)}")


class JobStateMachine:
    """Event-sourced view of every job's lifecycle state.

    All mutation goes through :meth:`record_g`, which journals the
    transition (charged) before applying it to the in-memory fold; the
    in-memory dicts are always a pure fold of the journal, so a crashed
    orchestrator's successor rebuilds exactly this object with
    :meth:`replay_g`.
    """

    def __init__(self, ctrl_kv: "KVNamespace | ShardedKVStore"):
        self.kv = ctrl_kv
        self._lock = threading.Lock()
        self._states: dict[int, str] = {}
        # Latest payload per (job_id, state) — e.g. the reconstructible
        # job spec at PENDING, the completion record at COMPLETED.
        self._payloads: dict[tuple[int, str], Any] = {}

    # -- read side ---------------------------------------------------------
    def state(self, job_id: int) -> str | None:
        with self._lock:
            return self._states.get(job_id)

    def payload(self, job_id: int, state: str) -> Any:
        with self._lock:
            return self._payloads.get((job_id, state))

    def jobs(self) -> dict[int, str]:
        with self._lock:
            return dict(self._states)

    def is_terminal(self, job_id: int) -> bool:
        return self.state(job_id) in TERMINAL_STATES

    # -- fold --------------------------------------------------------------
    def _apply(self, job_id: int, state: str, payload: Any) -> bool:
        """Fold one entry into the in-memory state. Returns False (and
        changes nothing) when the entry does not advance the job's
        rank — the idempotence that makes replay safe."""
        check_state(state)
        with self._lock:
            cur = self._states.get(job_id)
            if cur is not None and _RANK[state] <= _RANK[cur]:
                return False  # duplicate / regression / second terminal
            self._states[job_id] = state
            if payload is not None:
                self._payloads[(job_id, state)] = payload
            return True

    # -- write side --------------------------------------------------------
    def record_g(self, job_id: int, state: str, at_ms: float = 0.0,
                 payload: Any = None) -> Any:
        """Journal-then-apply one lifecycle transition (charged). A
        non-advancing transition is a no-op that is NOT journaled —
        recovery re-drives jobs through the same code path and must not
        grow the journal with duplicates. Returns True iff the job's
        state advanced."""
        check_state(state)
        with self._lock:
            cur = self._states.get(job_id)
            advances = cur is None or _RANK[state] > _RANK[cur]
        if not advances:
            return False
        entry = {"job_id": job_id, "state": state, "at_ms": at_ms}
        if payload is not None:
            entry["payload"] = payload
        yield from self.kv.journal_append_g(JOB_JOURNAL, entry)
        # Re-fold under the lock (another actor may have advanced the
        # job between the check and the append; _apply re-validates).
        self._apply(job_id, state, payload)
        return True

    def replay_g(self) -> Any:
        """Rebuild state from the journal (charged scan). Returns the
        number of entries folded. Safe to call on a machine that already
        holds state: non-advancing entries are skipped."""
        entries = yield from self.kv.journal_scan_g(JOB_JOURNAL)
        for e in entries:
            self._apply(e["job_id"], e["state"], e.get("payload"))
        return len(entries)

    def journal_len(self) -> int:
        return self.kv.journal_len(JOB_JOURNAL)
