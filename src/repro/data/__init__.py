from repro.data.pipeline import (
    DataConfig,
    TokenPipeline,
    pack_documents,
)

__all__ = ["DataConfig", "TokenPipeline", "pack_documents"]
