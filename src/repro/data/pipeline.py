"""Deterministic sharded token pipeline.

Production properties a 1000-node run needs, implemented without any
external dataset dependency (documents are synthesized from a seeded
PRNG; a real corpus plugs in by replacing ``_synth_document``):

- **host sharding**: host h of H reads only shard slices h, h+H, h+2H…
  so no two hosts ever touch the same document,
- **determinism + resumability**: the iterator state is a single
  ``(epoch, index)`` pair; restoring it replays the exact stream
  (checkpointed alongside model state for exactly-once semantics),
- **sequence packing**: documents are packed into fixed-length rows with
  EOS separators and loss masking across document boundaries — the
  standard trick that keeps MFU independent of document length,
- **WUKONG integration**: ``orchestrator.build_training_workflow``'s
  ``data_fn`` tasks call ``pipeline.batch(step)``; a failed/straggling
  load is retried by the engine like any other task.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    batch_per_host: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 512


def _synth_document(rng: np.random.Generator, cfg: DataConfig) -> np.ndarray:
    n = max(8, int(rng.exponential(cfg.mean_doc_len)))
    # zipf-ish unigram stream, clipped into vocab (never emits EOS)
    toks = rng.zipf(1.3, size=n) % (cfg.vocab - 1) + 1
    return toks.astype(np.int32)


def pack_documents(
    docs: list[np.ndarray], seq_len: int, eos_id: int
) -> tuple[np.ndarray, np.ndarray, list[np.ndarray]]:
    """Pack documents into one row of ``seq_len`` tokens.

    Returns (tokens, loss_mask, leftover_docs). The mask zeroes the
    position after each EOS so loss never crosses a document boundary.
    """
    row = np.empty(seq_len, dtype=np.int32)
    mask = np.ones(seq_len, dtype=np.float32)
    pos = 0
    rest: list[np.ndarray] = []
    for i, doc in enumerate(docs):
        if pos >= seq_len:
            rest.extend(docs[i:])
            break
        take = min(len(doc), seq_len - pos - 1)
        row[pos:pos + take] = doc[:take]
        if take < len(doc):
            rest.append(doc[take:])
            pos += take
            continue
        row[pos + take] = eos_id
        if pos + take + 1 < seq_len:
            mask[pos + take + 1] = 0.0  # next doc's first target
        pos += take + 1
    if pos < seq_len:
        row[pos:] = eos_id
        mask[pos:] = 0.0
    return row, mask, rest


class TokenPipeline:
    """Deterministic, resumable, host-sharded batch stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._doc_index = 0
        self._carry: list[np.ndarray] = []

    # -- resumable state -------------------------------------------------
    def state(self) -> dict:
        return {
            "doc_index": self._doc_index,
            "carry": [c.copy() for c in self._carry],
        }

    def restore(self, state: dict) -> None:
        self._doc_index = int(state["doc_index"])
        self._carry = [np.asarray(c, dtype=np.int32)
                       for c in state.get("carry", [])]

    # -- stream ----------------------------------------------------------
    def _doc(self, global_idx: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, global_idx))
        return _synth_document(rng, self.cfg)

    def _next_doc(self) -> np.ndarray:
        # host h owns documents h, h+H, h+2H, ...
        gidx = self._doc_index * self.cfg.n_hosts + self.cfg.host_id
        self._doc_index += 1
        return self._doc(gidx)

    def batch(self, step: int | None = None) -> dict[str, np.ndarray]:
        """One (batch_per_host, seq_len) packed batch. If ``step`` is
        given the pipeline first seeks deterministically so workflow
        tasks are idempotent under WUKONG retries."""
        if step is not None:
            # idempotent: derive position purely from step
            self._doc_index = step * self.cfg.batch_per_host * 4
            self._carry = []
        rows, masks = [], []
        for _ in range(self.cfg.batch_per_host):
            while sum(len(d) for d in self._carry) < self.cfg.seq_len:
                self._carry.append(self._next_doc())
            row, mask, self._carry = pack_documents(
                self._carry, self.cfg.seq_len, self.cfg.eos_id)
            rows.append(row)
            masks.append(mask)
        tokens = np.stack(rows)
        labels = np.roll(tokens, -1, axis=1)
        return {
            "tokens": tokens,
            "labels": labels,
            "loss_mask": np.stack(masks),
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.batch()
