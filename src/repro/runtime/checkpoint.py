"""Checkpointing: save/restore with resharding, async device→host copy.

- ``save``: device_get the pytree (optionally on a background thread so
  the training loop continues — async checkpointing) and write one .npz
  plus a manifest of tree paths.
- ``restore``: load and ``device_put`` with *target* shardings — the mesh
  at restore time may differ from the mesh at save time (elastic resume:
  scale the data axis up/down, or move single-pod ↔ multi-pod; parameter
  shapes are logical so any valid mesh works).
- crash safety: writes go to a temp name then ``os.replace`` (atomic).
"""
from __future__ import annotations

import os
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, step: int, async_: bool = False
         ) -> threading.Thread | None:
    """Write checkpoint. With ``async_=True`` returns the writer thread
    (device→host copy happens on the caller; file IO overlaps training)."""
    host = jax.tree.map(np.asarray, jax.device_get(tree))

    def write():
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        np.savez(tmp, __step__=np.asarray(step), **_flatten(host))
        os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(path: str) -> int | None:
    if not os.path.exists(path):
        return None
    with np.load(path) as z:
        return int(z["__step__"])


def restore(path: str, like: Any, shardings: Any | None = None
            ) -> tuple[Any, int]:
    """Load a checkpoint into the structure of ``like``; ``shardings``
    (optional pytree) reshards onto the *current* mesh (elastic resume)."""
    with np.load(path) as z:
        step = int(z["__step__"])
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for pth, leaf in flat_like:
            key = "/".join(str(p) for p in pth)
            arr = z[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape,
                                                    leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(
        jax.tree.structure(like), leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step
