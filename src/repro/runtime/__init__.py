"""Distributed training/serving runtime (sharding, steps, checkpoint,
orchestration)."""
