"""Cluster workflow orchestration — the paper's engine driving training.

The training *workflow* (not the inner jitted step) is expressed as a
WUKONG DAG: per-step tasks chain ``data_shard -> train_step -> metrics``,
with periodic checkpoint fan-outs. The DAG engine supplies the paper's
fault-tolerance machinery for free: a failed step task is re-invoked
(Lambda-retry analog), stragglers can be speculatively duplicated, and
idempotent KV writes make both safe. On a real multi-pod deployment each
Task Executor maps to one pod's coordinator process.

This is the TPU adaptation of the paper's decentralized scheduling to the
layer where JAX does *not* already schedule: between jitted regions
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.core import (
    DAG,
    EngineConfig,
    GraphBuilder,
    JobReport,
    WukongEngine,
)


@dataclasses.dataclass
class TrainRunResult:
    report: JobReport
    final_state_key: str
    metric_keys: list[str]


def build_training_workflow(
    n_steps: int,
    step_fn: Callable[[Any, int], tuple[Any, Any]],
    init_fn: Callable[[], Any],
    checkpoint_fn: Callable[[Any, int], Any] | None = None,
    checkpoint_every: int = 0,
    data_fn: Callable[[int], Any] | None = None,
) -> tuple[DAG, str, list[str]]:
    """Unrolled training chain as a DAG.

    ``step_fn(state, batch_or_step) -> (state, metrics)``. Checkpoint
    tasks fan out of the main chain (they never block the next step —
    async checkpointing expressed as graph structure).
    """
    g = GraphBuilder()
    state = g.add(init_fn, name="train/init")
    metric_keys: list[str] = []

    def make_step(i: int):
        def run_step(st, batch=None):
            new_state, metrics = step_fn(st, batch if batch is not None
                                         else i)
            return {"state": new_state, "metrics": metrics}

        run_step.__name__ = f"train_step_{i}"
        return run_step

    def get_state(d):
        return d["state"]

    def get_metrics(d):
        return d["metrics"]

    for i in range(n_steps):
        args = [state]
        if data_fn is not None:
            batch = g.add(lambda i=i: data_fn(i), name=f"data/shard-{i}")
            args.append(batch)
        out = g.add(make_step(i), *args, name=f"train/step-{i}")
        state = g.add(get_state, out, name=f"train/state-{i}")
        mk = f"train/metrics-{i}"
        g.add(get_metrics, out, name=mk)
        metric_keys.append(mk)
        if (checkpoint_fn is not None and checkpoint_every
                and (i + 1) % checkpoint_every == 0):
            g.add(lambda st, i=i: checkpoint_fn(st, i),
                  state, name=f"ckpt/step-{i}")
    # alias the terminal state so it is a DAG root even when a checkpoint
    # task also consumes it
    g.add(lambda s: s, state, name="train/final")
    return g.build(), "train/final", metric_keys


def run_training_workflow(
    dag: DAG, final_key: str, metric_keys: list[str],
    engine_config: EngineConfig | None = None,
) -> TrainRunResult:
    report = WukongEngine(engine_config or EngineConfig()).compute(dag)
    return TrainRunResult(report=report, final_state_key=final_key,
                          metric_keys=metric_keys)
