"""Serving step builders (decode with KV/SSM cache) and input specs."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig


def build_serve_step(cfg: ModelConfig):
    """(params, cache, batch) -> (logits, new_cache).

    ``batch`` = {"token": (B,) int32, "pos": () int32}. One new token per
    sequence against a cache of ``seq_len`` (the assignment's decode
    shapes).
    """

    def serve_step(params, cache, batch):
        return M.decode_step(params, cfg, cache, batch["token"],
                             batch["pos"])

    return serve_step


def decode_inputs(cfg: ModelConfig, batch: int, seq_len: int,
                  abstract: bool = False) -> dict[str, Any]:
    if abstract:
        return {
            "token": jax.ShapeDtypeStruct((batch,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    return {
        "token": jnp.zeros((batch,), dtype=jnp.int32),
        "pos": jnp.asarray(seq_len - 1, dtype=jnp.int32),
    }
