"""Logical-axis → mesh-axis resolution (DP / FSDP / TP / EP / SP + pod).

Model code annotates parameters with *logical* axis names
(repro.models.layers). This module resolves them to ``PartitionSpec``s for
a concrete mesh, with a shape-aware divisibility guard: a mesh axis is
only applied to a tensor dim it divides evenly — otherwise that dim falls
back to replicated. This keeps one rule-set valid across all 10 archs
(e.g. xLSTM's 4 heads cannot shard over a 16-way model axis; its
projection matrices still shard on the flat head*dim axis).

Parallelism layout (the §Perf baseline):
- batch        → ("pod", "data") — pure DP across pods, lowest DCN traffic
- heads/ff/vocab/inner → "model" — Megatron-style tensor parallelism
- embed (weights' d_model dim) → "data" when ``fsdp=True`` — ZeRO-3-style
  weight+optimizer sharding, all-gathered per layer under the scan
  (overlaps with compute via XLA latency hiding)
- experts      → "model" when E divides the axis (EP), else TP-over-ff
- kv_seq       → "model" for decode KV caches — sequence parallelism for
  long-context serving (attention softmax reductions become collectives)
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def rules_for(mesh: Mesh, *, fsdp: bool, shard_kv_seq: bool = False,
              expert_parallel: bool = True,
              tensor_parallel: bool = True) -> dict[str, Any]:
    """``tensor_parallel=False`` replicates weights over the model axis
    and lets the model axis carry extra batch instead — right for small
    models whose per-op shards would be sliver-sized (xLSTM, SmolLM,
    Whisper), where TP collectives dominate the roofline."""
    tp = "model" if tensor_parallel else None
    batch = batch_axes(mesh)
    if not tensor_parallel:
        batch = batch + ("model",)
    return {
        "vocab": tp,
        "embed": "data" if fsdp else None,
        "heads": tp,
        "kv_heads": tp,
        "head_dim": None,
        "ff": tp,
        "experts": tp if expert_parallel else None,
        "layers": None,
        "inner": tp,
        "state": None,
        "batch": batch,
        "kv_seq": "model" if (shard_kv_seq and tensor_parallel) else None,
        None: None,
    }


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def resolve_spec(
    spec: tuple, shape: tuple[int, ...], mesh: Mesh, rules: dict[str, Any],
) -> P:
    """Logical spec tuple + concrete shape -> PartitionSpec.

    Drops any mesh axis that does not divide the corresponding dim, and
    never uses one mesh axis twice in a single spec.
    """
    assert len(spec) == len(shape), (spec, shape)
    used: set[str] = set()
    out = []
    for logical, dim in zip(spec, shape):
        axis = rules.get(logical)
        flat = axis if isinstance(axis, tuple) else (
            (axis,) if axis else ())
        if axis is None or any(a in used for a in flat):
            out.append(None)
            continue
        if dim % _axis_size(mesh, axis) != 0:
            out.append(None)
            continue
        used.update(flat)
        out.append(axis)
    return P(*out)


def _is_spec_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def tree_shardings(
    abstract: Any, specs: Any, mesh: Mesh, rules: dict[str, Any],
) -> Any:
    """NamedShardings for a pytree given its abstract shapes and logical
    specs (parallel trees)."""
    flat_a, treedef = jax.tree.flatten(abstract)
    flat_s = jax.tree.flatten(specs, is_leaf=_is_spec_leaf)[0]
    assert len(flat_a) == len(flat_s), (len(flat_a), len(flat_s))
    out = [
        NamedSharding(mesh, resolve_spec(s, a.shape, mesh, rules))
        for a, s in zip(flat_a, flat_s)
    ]
    return jax.tree.unflatten(treedef, out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, ndim: int = 2,
                   dim0: int | None = None) -> NamedSharding:
    """Shard dim 0 (global batch) over the data axes; replicate the rest.

    When ``dim0`` is given and is not divisible by the data-axes extent
    (e.g. long_500k's global_batch=1), dim 0 falls back to replicated —
    the model axis still provides parallelism for such cells."""
    axes = batch_axes(mesh)
    if dim0 is not None and dim0 % _axis_size(mesh, axes) != 0:
        return NamedSharding(mesh, P(*([None] * ndim)))
    return NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))
