"""Training step: loss + grads + AdamW, with microbatch accumulation.

``build_train_step`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` ready for
``jax.jit`` with shardings. Gradient accumulation over microbatches is a
``lax.scan`` so activation memory is one microbatch while the weight
gradient buffer lives across the scan (standard large-batch trick; also
the knob §Perf turns for memory-bound cells).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_update
from repro.optim.schedules import cosine_schedule


def build_train_step(cfg: ModelConfig, opt: AdamWConfig,
                     n_microbatches: int = 1):
    def loss_of(params, tokens, labels, enc):
        return M.loss_fn(params, cfg, tokens, labels, enc)

    def train_step(params, opt_state, batch):
        tokens = batch["tokens"]
        labels = batch["labels"]
        enc = batch.get("enc_embeds")
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(
                params, tokens, labels, enc)
        else:
            B = tokens.shape[0]
            assert B % n_microbatches == 0
            mb = B // n_microbatches

            def split(x):
                return x.reshape((n_microbatches, mb) + x.shape[1:])

            mtok, mlab = split(tokens), split(labels)
            menc = split(enc) if enc is not None else None

            def acc_step(carry, xs):
                loss_acc, g_acc = carry
                t, l = xs[0], xs[1]
                e = xs[2] if menc is not None else None
                loss, g = jax.value_and_grad(loss_of)(params, t, l, e)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (mtok, mlab) + ((menc,) if menc is not None else ())
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros(()), zeros), xs)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)

        lr_scale = cosine_schedule(opt_state["count"], warmup=opt.warmup)
        params, opt_state, om = adamw_update(
            grads, opt_state, params, opt, lr_scale)
        metrics = {"loss": loss, "grad_norm": om["grad_norm"],
                   "lr_scale": lr_scale}
        return params, opt_state, metrics

    return train_step


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                    abstract: bool = False) -> dict[str, Any]:
    """Synthetic token batch (data pipeline stand-in / dry-run specs)."""
    if abstract:
        out = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
        if cfg.enc_dec:
            out["enc_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.enc_frames, cfg.d_model), jnp.bfloat16
                if cfg.dtype == "bfloat16" else jnp.float32)
        return out
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab)
    out = {"tokens": toks,
           "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.enc_dec:
        out["enc_embeds"] = jax.random.normal(
            key, (batch, cfg.enc_frames, cfg.d_model),
            dtype=jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    return out
